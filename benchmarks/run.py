"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per block.  Default is quick mode
(2 SNNs, short profiling window — CI-friendly); ``--full`` reproduces the
paper-scale runs (all 5 SNNs at Table 1 spike counts) used in
EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (all 5 SNNs, Table 1 spike counts)")
    ap.add_argument("--only", choices=["partition", "mapping",
                                       "mapping_engine", "overall",
                                       "exec_time", "kernels", "nocsim",
                                       "faults", "sweep", "scale"])
    args = ap.parse_args()

    from . import (bench_exec_time, bench_faults, bench_kernels,
                   bench_mapping_algos, bench_nocsim, bench_overall,
                   bench_partition, bench_scale, bench_sweep)

    suites = {
        "partition": bench_partition.run,
        "mapping": bench_mapping_algos.run,
        "mapping_engine": bench_mapping_algos.run_engines,
        "overall": bench_overall.run,
        "exec_time": bench_exec_time.run,
        "kernels": bench_kernels.run,
        "nocsim": bench_nocsim.run,
        "faults": bench_faults.run,
        "sweep": bench_sweep.run,
        "scale": bench_scale.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}
    t0 = time.perf_counter()
    for name, fn in suites.items():
        print(f"\n=== {name} ===", file=sys.stderr)
        fn(full=args.full)
    print(f"\n# benchmarks done in {time.perf_counter() - t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()

"""Paper Fig. 5 + 6: SA / PSO / Tabu convergence and mapping-phase metrics
(latency, dynamic energy, congestion, edge variance) normalized to PSO
(SpiNeMap's placer)."""
from __future__ import annotations

import numpy as np

from repro.core import MAPPERS, sneap_partition, traffic_matrix
from repro.nocsim import simulate_noc

from .common import emit, get_profile, scale


def run(full: bool = False) -> list[dict]:
    s = scale(full)
    rows = []
    for snn in s["snns"]:
        prof = get_profile(snn, full)
        part = sneap_partition(prof.graph, capacity=256, seed=0)
        mesh_w = 5 if part.k <= 25 else 8
        cores = mesh_w * mesh_w
        traffic = traffic_matrix(part.part, prof.trace_src, prof.trace_dst, part.k)
        budgets = {"sa": s["sa_iters"], "pso": s["pso_iters"], "tabu": s["tabu_iters"]}
        # queued (cycle-stepped) sim for tractable traces; analytic for the
        # multi-10M-spike nets (same Eq-3 congestion & edge variance; latency
        # becomes pure hop count — documented in EXPERIMENTS.md).
        mode = "queued" if prof.num_spikes < 6_000_000 else "analytic"
        metrics = {}
        for algo, fn in MAPPERS.items():
            res = fn(traffic, cores, mesh_w, prof.num_spikes, seed=0,
                     iters=budgets[algo])
            noc = simulate_noc(prof.trace_t, prof.trace_src, prof.trace_dst,
                               part.part, res.placement, mesh_w, mesh_w,
                               mode=mode)
            metrics[algo] = (res, noc)
        pso_noc = metrics["pso"][1]
        for algo, (res, noc) in metrics.items():
            conv = ";".join(f"{t:.2f}:{h:.4f}" for t, h in res.history[:12])
            rows.append({
                "name": f"mapping/{snn}/{algo}",
                "us_per_call": round(res.seconds * 1e6, 1),
                "derived": (
                    f"avg_hop={res.avg_hop:.4f};"
                    f"latency_vs_pso={noc.avg_latency / max(pso_noc.avg_latency, 1e-9):.3f};"
                    f"energy_vs_pso={noc.dynamic_energy_pj / max(pso_noc.dynamic_energy_pj, 1e-9):.3f};"
                    f"congestion_vs_pso={noc.congestion_count / max(pso_noc.congestion_count, 1):.3f};"
                    f"edgevar_vs_pso={noc.edge_variance / max(pso_noc.edge_variance, 1e-9):.3f};"
                    f"evals={res.evaluations};conv={conv}"
                ),
            })
    emit(rows, "Fig5/6: mapper comparison (normalized to PSO)")
    return rows


if __name__ == "__main__":
    run(full=True)

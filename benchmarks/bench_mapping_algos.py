"""Mapping-phase benchmarks.

Two sections:

* ``run`` — paper Fig. 5 + 6: SA / PSO / Tabu convergence and
  mapping-phase metrics (latency, dynamic energy, congestion, edge
  variance) normalized to PSO (SpiNeMap's placer).
* ``run_engines`` — old-vs-new rows for the unified mapping engine
  (trajectory ``mapping_engine/*``): scalar SA chain vs the batched
  swap-delta engine, under both the pairwise Eq. 2 objective and the
  tree-hop objective, at equal proposal budgets; plus a toolchain row
  placing the bench SNN under ``cast="multicast"`` with tree vs pairwise
  placement.  Every engine row carries a ``parity`` column (``ok`` when
  the batched engine's quality is within tolerance of the scalar chain,
  ``MISMATCH`` otherwise) so `--smoke` in CI turns quality regressions
  red, the way ``bench_nocsim.py --smoke`` gates replay parity.  The full
  run records ``results/bench_mapping_engine.csv``.
"""
from __future__ import annotations

import csv
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import MAPPERS, run_toolchain, sneap_partition, traffic_matrix
from repro.core.graph import build_hypergraph
from repro.core.mapping import sa_search
from repro.core.placecost import TreeHopObjective
from repro.nocsim import simulate_noc

from .common import emit, get_profile, scale

ENGINE_CSV = Path("results/bench_mapping_engine.csv")


def run(full: bool = False) -> list[dict]:
    s = scale(full)
    rows = []
    for snn in s["snns"]:
        prof = get_profile(snn, full)
        part = sneap_partition(prof.graph, capacity=256, seed=0)
        mesh_w = 5 if part.k <= 25 else 8
        cores = mesh_w * mesh_w
        traffic = traffic_matrix(part.part, prof.trace_src, prof.trace_dst, part.k)
        budgets = {"sa": s["sa_iters"], "pso": s["pso_iters"], "tabu": s["tabu_iters"]}
        # queued (cycle-stepped) sim for tractable traces; analytic for the
        # multi-10M-spike nets (same Eq-3 congestion & edge variance; latency
        # becomes pure hop count — documented in EXPERIMENTS.md).
        mode = "queued" if prof.num_spikes < 6_000_000 else "analytic"
        metrics = {}
        for algo in ("sa", "pso", "tabu"):
            res = MAPPERS[algo](traffic, cores, mesh_w, prof.num_spikes, seed=0,
                                iters=budgets[algo])
            noc = simulate_noc(prof.trace_t, prof.trace_src, prof.trace_dst,
                               part.part, res.placement, mesh_w, mesh_w,
                               mode=mode)
            metrics[algo] = (res, noc)
        pso_noc = metrics["pso"][1]
        for algo, (res, noc) in metrics.items():
            conv = ";".join(f"{t:.2f}:{h:.4f}" for t, h in res.history[:12])
            rows.append({
                "name": f"mapping/{snn}/{algo}",
                "us_per_call": round(res.seconds * 1e6, 1),
                "derived": (
                    f"avg_hop={res.avg_hop:.4f};"
                    f"latency_vs_pso={noc.avg_latency / max(pso_noc.avg_latency, 1e-9):.3f};"
                    f"energy_vs_pso={noc.dynamic_energy_pj / max(pso_noc.dynamic_energy_pj, 1e-9):.3f};"
                    f"congestion_vs_pso={noc.congestion_count / max(pso_noc.congestion_count, 1):.3f};"
                    f"edgevar_vs_pso={noc.edge_variance / max(pso_noc.edge_variance, 1e-9):.3f};"
                    f"evals={res.evaluations};conv={conv}"
                ),
            })
    emit(rows, "Fig5/6: mapper comparison (normalized to PSO)")
    return rows


def _synth_pairwise(k: int, seed: int = 0) -> tuple[np.ndarray, int]:
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 200, (k, k)).astype(np.float64)
    np.fill_diagonal(c, 0)
    return c, int(c.sum())


def _synth_tree(n: int, fan: int, k: int, cores: int, mesh_w: int,
                seed: int = 0) -> TreeHopObjective:
    """Fan-out SNN hypergraph + random partition: the regime where replicas
    share XY-tree prefixes and pairwise hop cost over-counts."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), fan)
    dst = rng.integers(0, n, n * fan)
    fire = rng.integers(1, 20, n)
    hyper = build_hypergraph(n, src, dst, fire)
    part = rng.integers(0, k, n)
    return TreeHopObjective(hyper, part, cores, mesh_w, cores // mesh_w)


def _tree_traffic(obj: TreeHopObjective, k: int) -> tuple[np.ndarray, int]:
    """Multicast packet counts of the tree instance as a (k, k) pairwise
    traffic matrix — one packet per (firing, dest partition) — so the tree
    engine rows report a meaningful Fig. 5 avg_hop alongside the tree cost."""
    traffic = np.zeros((k, k), dtype=np.float64)
    lens = np.diff(obj.tptr)
    np.add.at(traffic, (np.repeat(obj.tsrc, lens), obj.tdst),
              np.repeat(obj.tw, lens))
    return traffic, int(traffic.sum())


def _engine_row(name: str, objective: str, traffic, trace_len, cores, mesh_w,
                iters: int, tol: float, obj_factory=None,
                repeats: int = 3, eq_clock: bool = False) -> dict:
    """Scalar SA chain vs batched engine at an equal proposal budget.

    Searches are seed-deterministic, so quality comes from one run and the
    wall-time is the min over ``repeats`` runs (scheduler-noise floor).

    With ``eq_clock`` the batched engine is additionally re-run at an
    equal *wall-clock* budget (its proposal budget scaled up by the
    measured speedup): the throughput fields still compare equal
    proposals, and the ``eqclock_*`` fields show what the freed budget
    buys — the batched engine passes parity if either run's quality
    lands within tolerance of the scalar chain.
    """
    def timed(impl, n_iters, n_repeats):
        best, result = float("inf"), None
        for _ in range(n_repeats):
            kwargs = {} if obj_factory is None else {"objective": obj_factory()}
            t0 = time.perf_counter()
            result = sa_search(traffic, cores, mesh_w, trace_len, seed=0,
                               iters=n_iters, impl=impl, **kwargs)
            best = min(best, time.perf_counter() - t0)
        return result, best

    scalar, t_scalar = timed("scalar", iters, repeats)
    vec, t_vec = timed("vec", iters, repeats)
    # Quality gate in the units the engines optimized; plus the pairwise
    # Fig. 5 number for cross-objective comparability.
    s_cost = scalar.tree_hop if objective == "tree" else scalar.avg_hop
    v_cost = vec.tree_hop if objective == "tree" else vec.avg_hop
    eq = ""
    best_cost = v_cost
    if eq_clock and t_vec < t_scalar:
        it2 = int(round(iters * t_scalar / max(t_vec, 1e-9)))
        veq, t_eq = timed("vec", it2, 1)
        e_cost = veq.tree_hop if objective == "tree" else veq.avg_hop
        best_cost = min(best_cost, e_cost)
        eq = (
            f"eqclock_iters={it2};eqclock_time_s={t_eq:.3f};"
            f"cost_vec_eqclock={e_cost:.4f};"
            f"eqclock_delta={(e_cost / max(s_cost, 1e-12) - 1) * 100:+.2f}%;"
        )
    parity = "ok" if best_cost <= s_cost * (1 + tol) + 1e-12 else "MISMATCH"
    return {
        "name": f"mapping_engine/{name}",
        "us_per_call": round(t_vec * 1e6, 1),
        "derived": (
            f"objective={objective};cores={cores};iters={iters};"
            f"time_scalar_s={t_scalar:.3f};time_vec_s={t_vec:.3f};"
            f"speedup={t_scalar / max(t_vec, 1e-9):.1f}x;"
            f"cost_scalar={s_cost:.4f};cost_vec={v_cost:.4f};"
            f"quality_delta={(v_cost / max(s_cost, 1e-12) - 1) * 100:+.2f}%;"
            f"{eq}"
            f"avg_hop_scalar={scalar.avg_hop:.4f};avg_hop_vec={vec.avg_hop:.4f};"
            f"parity={parity}"
        ),
    }


def _toolchain_row(small: bool) -> dict:
    """SNEAP under cast="multicast": tree-objective placement (the default)
    vs pairwise placement, judged by what the NoC replay measures."""
    prof = get_profile("smooth_320", full=False)
    iters = 4_000 if small else 12_000
    res = {}
    for po in ("tree", "pairwise"):
        t0 = time.perf_counter()
        r = run_toolchain(prof, method="sneap", mesh_w=5, mesh_h=5,
                          capacity=16, seed=0, cast="multicast",
                          place_objective=po, mapper_kwargs={"iters": iters})
        res[po] = (r.summary(), time.perf_counter() - t0)
    st, tt = res["tree"]
    sp, tp = res["pairwise"]
    wins = (st["energy_pj"] <= sp["energy_pj"] + 1e-9
            or st["avg_latency"] <= sp["avg_latency"] + 1e-9)
    # Informational at small budgets (seed-noisy); a gate on the full run,
    # where the tree objective must pay off on the replay.
    parity = "info" if small else ("ok" if wins else "MISMATCH")
    return {
        "name": "mapping_engine/toolchain_multicast_tree_vs_pairwise",
        "us_per_call": round(tt * 1e6, 1),
        "derived": (
            f"snn=smooth_320;k={st['k']};iters={iters};"
            f"energy_tree={st['energy_pj']:.0f};energy_pairwise={sp['energy_pj']:.0f};"
            f"lat_tree={st['avg_latency']:.4f};lat_pairwise={sp['avg_latency']:.4f};"
            f"tree_hop_tree={st['tree_hop']:.4f};tree_hop_pairwise={sp['tree_hop']:.4f};"
            f"avg_hop_tree={st['avg_hop']:.4f};avg_hop_pairwise={sp['avg_hop']:.4f};"
            f"parity={parity}"
        ),
    }


def run_engines(full: bool = False, smoke: bool = False) -> list[dict]:
    # Quick mode (neither --full nor --smoke, e.g. via `benchmarks.run`)
    # uses the smoke sizing: paper-scale engine rows belong to the full
    # run, which is also the only one recording ENGINE_CSV.
    small = smoke or not full
    if small:
        pw = dict(k=48, cores=64, mesh_w=8, iters=8_000)
        tr = dict(n=1024, fan=6, k=48, cores=64, mesh_w=8, iters=1_500)
        # Smoke-sized versions of the 16x16 / 1024-core meshes the full
        # run measures at paper scale, so CI exercises the aggregate
        # engine at both mesh geometries (mesh_w == mesh_h and the tall
        # clamp path) on every push.
        tr16 = dict(n=2048, fan=6, k=160, cores=256, mesh_w=16, iters=800)
        tr32 = dict(n=4096, fan=6, k=640, cores=1024, mesh_w=32, iters=600)
        # small budgets are noisier; the full run gates tighter
        pw_tol, tree_tol, repeats = 0.10, 0.15, 2
    else:
        pw = dict(k=200, cores=256, mesh_w=16, iters=60_000)
        tr = dict(n=4096, fan=8, k=200, cores=256, mesh_w=16, iters=6_000)
        tr16 = None  # the main tree row is already 16x16 / 256 cores
        tr32 = dict(n=16384, fan=8, k=800, cores=1024, mesh_w=32, iters=6_000)
        # The acceptance gate is the pairwise row: batched within 2% of
        # the scalar chain's avg_hop.  The tree objective's lumpier
        # landscape tolerates batched application a bit worse (stale
        # deltas across a committed subset); 8% bounds it without gating
        # the throughput row on SA noise — and the equal-wall-clock rerun
        # must land within the same band (it lands *below* the scalar
        # chain in practice: the freed budget buys back the quality).
        pw_tol, tree_tol, repeats = 0.02, 0.08, 3
    traffic, trace_len = _synth_pairwise(pw["k"])

    def tree_row(name, cfg):
        factory = lambda: _synth_tree(cfg["n"], cfg["fan"], cfg["k"],  # noqa: E731
                                      cfg["cores"], cfg["mesh_w"])
        tt, tl = _tree_traffic(factory(), cfg["k"])
        return _engine_row(name, "tree", tt, tl, cfg["cores"], cfg["mesh_w"],
                           cfg["iters"], tree_tol, obj_factory=factory,
                           repeats=repeats, eq_clock=True)

    rows = [
        _engine_row("sa_pairwise_scalar_vs_batched", "pairwise", traffic,
                    trace_len, pw["cores"], pw["mesh_w"], pw["iters"],
                    pw_tol, repeats=repeats),
        tree_row("sa_tree_scalar_vs_batched", tr),
    ]
    if tr16 is not None:
        rows.append(tree_row("sa_tree_16x16_scalar_vs_batched", tr16))
    rows.append(tree_row("sa_tree_32x32_scalar_vs_batched", tr32))
    rows.append(_toolchain_row(small))
    emit(rows, "Mapping engine: scalar SA chain vs batched swap-delta engine "
               "(old-vs-new, pairwise + tree objectives)")
    if full:
        ENGINE_CSV.parent.mkdir(parents=True, exist_ok=True)
        with ENGINE_CSV.open("w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run_engines(smoke=True)
    elif "--engines" in sys.argv:
        run_engines(full=True)
    else:
        run(full="--quick" not in sys.argv)

"""Paper Fig. 8: end-to-end toolchain execution time (partition + map),
SNEAP vs SpiNeMap.  The paper's 418x comes from multilevel partitioning
replacing full-graph greedy KL and SA's faster convergence replacing PSO;
both effects are measured here on identical profiled traces."""
from __future__ import annotations

from repro.core import run_toolchain

from .common import emit, get_profile, scale


def run(full: bool = False) -> list[dict]:
    s = scale(full)
    rows = []
    for snn in s["snns"]:
        prof = get_profile(snn, full)
        mesh_w = 5 if prof.num_neurons <= 25 * 256 else 8
        # Match optimization quality budgets: SA iterations vs PSO's
        # population x generations so neither gets an unfair tiny budget.
        sneap = run_toolchain(prof, method="sneap", mesh_w=mesh_w, mesh_h=mesh_w,
                              seed=0, noc_mode="analytic",
                              mapper_kwargs={"iters": s["sa_iters"]})
        spine = run_toolchain(prof, method="spinemap", mesh_w=mesh_w,
                              mesh_h=mesh_w, seed=0, noc_mode="analytic",
                              mapper_kwargs={"iters": s["pso_iters"]})
        t_sneap = sneap.phase_seconds["partition"] + sneap.phase_seconds["mapping"]
        t_spine = spine.phase_seconds["partition"] + spine.phase_seconds["mapping"]
        rows.append({
            "name": f"exec_time/{snn}",
            "us_per_call": round(t_sneap * 1e6, 1),
            "derived": (
                f"sneap_s={t_sneap:.3f};spinemap_s={t_spine:.3f};"
                f"speedup={t_spine / max(t_sneap, 1e-9):.1f}x;"
                f"sneap_hop={sneap.mapping.avg_hop:.4f};"
                f"spinemap_hop={spine.mapping.avg_hop:.4f};"
                f"partition_speedup={spine.phase_seconds['partition'] / max(sneap.phase_seconds['partition'], 1e-9):.1f}x"
            ),
        })
    emit(rows, "Fig8: end-to-end toolchain execution time")
    return rows


if __name__ == "__main__":
    run(full=True)

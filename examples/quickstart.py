"""SNEAP quickstart: profile -> partition -> map -> evaluate, vs baselines.

    PYTHONPATH=src python examples/quickstart.py [--snn smooth_320]

Reproduces the paper's four-phase toolchain on one of the five evaluated
SNNs and prints the Fig. 7 metrics for SNEAP / SpiNeMap / SCO.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import run_toolchain
from repro.snn import PAPER_SNNS, make_snn, profile_snn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snn", default="smooth_320", choices=PAPER_SNNS)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--mesh", type=int, default=5, help="mesh side (5 => 5x5)")
    args = ap.parse_args()

    print(f"[1/4] profiling {args.snn} ({args.steps} steps of LIF simulation)")
    topo = make_snn(args.snn)
    prof = profile_snn(topo, num_steps=args.steps, seed=0)
    print(f"      {prof.num_neurons} neurons, {prof.graph.num_edges} synapses, "
          f"{prof.num_spikes:,} spike transmissions")

    print("[2-4/4] partition -> map -> NoC-evaluate, three toolchains:")
    header = (f"      {'method':10s} {'k':>3s} {'cut':>9s} {'avg_hop':>8s} "
              f"{'latency':>8s} {'energy_pJ':>12s} {'congest':>8s} {'edge_var':>10s}")
    print(header)
    for method in ("sneap", "spinemap", "sco"):
        budget = {"sneap": {"iters": 20_000}, "spinemap": {"iters": 80},
                  "sco": {}}[method]
        r = run_toolchain(prof, method=method, mesh_w=args.mesh,
                          mesh_h=args.mesh, seed=0, mapper_kwargs=budget)
        print(f"      {method:10s} {r.partition.k:3d} {r.partition.edge_cut:9d} "
              f"{r.mapping.avg_hop:8.4f} {r.noc.avg_latency:8.3f} "
              f"{r.noc.dynamic_energy_pj:12.1f} {r.noc.congestion_count:8d} "
              f"{r.noc.edge_variance:10.1f}   "
              f"[partition {r.phase_seconds['partition']:.2f}s, "
              f"map {r.phase_seconds['mapping']:.2f}s]")
    print("\nLower is better on every column; SNEAP should win each (paper Fig. 7).")


if __name__ == "__main__":
    main()

"""Beyond-paper: SNEAP as the TPU device-layout optimizer.

    PYTHONPATH=src python examples/sneap_mesh_layout.py [--arch llama3-8b]

Reads the per-axis collective volumes of an architecture's train step from
the dry-run ledger (results/dryrun.jsonl), treats logical devices as SNN
"partitions" and collective bytes as "spikes", and runs the paper's SA
placer with torus distance to order devices for `make_mesh` — the same
partition-placement problem SNEAP solves for crossbar cores, one level up
the hierarchy (DESIGN.md §3).
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.sharding.layout import sneap_device_layout


def axis_bytes_from_dryrun(arch: str, ledger: Path) -> dict:
    """Split the measured per-chip collective bytes between mesh axes.

    Heuristic split grounded in the sharding plan: all-gather/all-to-all
    traffic rides the model axis (weight/activation gathers); all-reduce is
    gradient+activation, mostly data-axis in training.
    """
    best = None
    for line in ledger.read_text().splitlines():
        r = json.loads(line)
        if r.get("arch") == arch and r.get("shape") == "train_4k" \
                and r.get("mesh") == "16x16" and r.get("status") == "ok":
            best = r
    if best is None:
        raise SystemExit(f"no dry-run record for {arch}; run launch.dryrun first")
    coll = best["collectives"]
    model_bytes = coll.get("all-gather", 0) + coll.get("all-to-all", 0) \
        + coll.get("collective-permute", 0)
    data_bytes = coll.get("all-reduce", 0) + coll.get("reduce-scatter", 0)
    return {"model": float(model_bytes), "data": float(data_bytes)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ledger", default="results/dryrun.jsonl")
    ap.add_argument("--iters", type=int, default=60_000)
    args = ap.parse_args()

    axis_bytes = axis_bytes_from_dryrun(args.arch, Path(args.ledger))
    print(f"[layout] {args.arch}: per-chip collective bytes/step "
          f"model-axis={axis_bytes['model']:.3e} data-axis={axis_bytes['data']:.3e}")

    print("\n-- scenario 1: intact 16x16 torus --")
    order, base, opt = sneap_device_layout(
        {"data": 16, "model": 16}, axis_bytes, phys_w=16, iters=args.iters)
    print(f"[layout] hop-weighted bytes: default {base:.4f} -> SNEAP {opt:.4f} "
          f"({(1 - opt / max(base, 1e-12)) * 100:.1f}% lower; row-major is "
          "already optimal for ring traffic, SNEAP must only match it)")

    print("\n-- scenario 2: degraded pod, 4 dead chips (elastic remesh) --")
    # 252 healthy chips -> 14x18-equivalent logical (14 data x 18 model);
    # here: keep (data=14, model=18) = 252 logical devices on the holey grid.
    dead = [17, 100, 118, 203]
    order, base, opt = sneap_device_layout(
        {"data": 14, "model": 18}, axis_bytes, phys_w=16, iters=args.iters,
        dead_chips=dead)
    print(f"[layout] dead={dead}: naive compaction {base:.4f} -> SNEAP "
          f"{opt:.4f} ({(1 - opt / max(base, 1e-12)) * 100:.1f}% lower)")
    print("[layout] feed into repro.launch.mesh.make_mesh_with_layout(order)")


if __name__ == "__main__":
    main()

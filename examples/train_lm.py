"""End-to-end training driver with fault tolerance demo.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --fail-at 150
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume

Trains a ~27M-parameter llama-family model (4 layers, d=512) on the
deterministic synthetic-LM pipeline; loss drops from ~ln(V) to near zero
as the model learns the repeat task.  --fail-at N kills the process at
step N; --resume restores the last committed checkpoint and continues
bit-exactly (see tests/test_train_integration.py).
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/sneap_train_ckpt")
    ap.add_argument("--fail-at", type=int)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # ~27M params: llama-family at width 512 (same code path as llama3-8b).
    cfg = dataclasses.replace(
        get_config("llama3-8b"),
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, param_dtype="float32",
        activation_dtype="float32", name="llama-27m")
    mesh = make_local_mesh()
    out = train_loop(cfg, mesh, steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                     resume=args.resume, fail_at=args.fail_at, lr=1e-3,
                     log_every=20)
    print(f"final loss: {out['final_loss']:.4f} "
          f"({out['seconds']:.0f}s total)")


if __name__ == "__main__":
    main()

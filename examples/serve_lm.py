"""End-to-end serving driver: batched requests against a small model.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --batch 8

Prefills a batch of prompts with the same prefill/serve steps the
multi-pod dry-run lowers, then decodes with greedy sampling, reporting
prefill latency and per-token decode latency.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()  # CPU-scale weights, same code path
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    frontend = None
    if cfg.family in ("vlm", "audio"):
        frontend = rng.standard_normal(
            (args.batch, cfg.frontend_seq, cfg.frontend_dim)).astype(np.float32)
    res = serve_batch(cfg, mesh, prompts, args.gen,
                      temperature=args.temperature, frontend=frontend)
    print("sample generations (first 12 tokens per request):")
    for i, row in enumerate(res["tokens"][: min(args.batch, 4)]):
        print(f"  req{i}: {row[:12].tolist()}")


if __name__ == "__main__":
    main()

"""Batched toolchain sweep with a Pareto report.

    PYTHONPATH=src python examples/sweep_pareto.py [--snn smooth_320]

One `run_toolchain` call answers "how does this SNN behave on this
mesh?". Production asks a different question — "which (k, mesh,
objective, mapper, seed) is *best* for this workload?" — and answering
it one sequential call at a time wastes everything the configs share.
`repro.launch.sweep.run_sweep` runs a whole config grid at once:

  * partition/traffic phases are computed once per unique
    (method, capacity, k, objective, seed) and shared across configs;
  * same-shape `sa_jax` searches run as ONE vmapped device program;
  * `stepper="jax"` replays share pow2-padded compiled programs.

Rows are bitwise-identical to what sequential `run_toolchain` calls
would produce (the `benchmarks/bench_sweep.py` parity gate proves it),
so the sweep is a pure wall-clock win. This example sweeps two meshes x
two partition objectives x mappers x seeds and prints the Pareto front
over (energy, latency, toolchain seconds).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.sweep import config_grid, run_sweep
from repro.snn import PAPER_SNNS, make_snn, profile_snn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snn", default="smooth_320", choices=PAPER_SNNS)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    print(f"[profile] {args.snn} ({args.steps} LIF steps)")
    prof = profile_snn(make_snn(args.snn), num_steps=args.steps, seed=0)

    # 2 meshes x 2 objectives x 2 seeds, device-batched sa_jax half plus
    # a host-SA half — 10 configs, far fewer unique partitions.
    grid = config_grid(
        mesh=[(4, 4), (6, 6)], seed=[0, 1], objective=["cut", "volume"],
        mapper=["sa_jax"], mapper_kwargs=[{"iters": 4000, "chains": 8}],
        stepper=["jax"],
    ) + config_grid(
        mesh=[(4, 4), (6, 6)], seed=[0], objective=["cut"], mapper=["sa"],
        mapper_kwargs=[{"iters": 4000}],
    )
    print(f"[sweep]   {len(grid)} configs")
    res = run_sweep(prof, grid, progress=lambda m: print(f"          {m}"))
    print(f"          done in {res.seconds:.2f}s")

    print(f"\nPareto front over {' x '.join(res.pareto_keys)} "
          f"({len(res.front())} of {len(res.rows)} configs):")
    hdr = (f"  {'mesh':>5s} {'mapper':>7s} {'obj':>7s} {'seed':>4s} {'k':>3s} "
           f"{'energy_pJ':>12s} {'latency':>8s} {'tool_s':>7s}")
    print(hdr)
    for r in res.front():
        print(f"  {r['mesh_w']}x{r['mesh_h']:<3} {r['mapper']:>7s} "
              f"{r['objective']:>7s} {r['seed']:>4} {r['k']:>3} "
              f"{float(r['energy_pj']):12.1f} {float(r['avg_latency']):8.3f} "
              f"{float(r['total_s']):7.2f}")
    print("\nEvery front row is a defensible deployment choice; dominated "
          "rows lose on all three axes at once.")


if __name__ == "__main__":
    main()
